"""PR 8: the fleet routing index — bitwise parity with the seed rank path.

The contract under test is absolute: :class:`repro.fleet.index.RoutingIndex`
must reproduce the seed full-sort ``CostRouter.rank`` order *bitwise* — the
same devices, identically ordered, across arbitrary fleet shapes, placement
churn, power gating, bare epoch bumps, tariff refreshes and subset pools —
while ``stateless_rank=False`` routers (round-robin, random) never touch
the index at all.
"""

from __future__ import annotations

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import (ZoneTariff, cluster_workload, make_zone,
                           make_zone_router, run_cluster)
from repro.core.planner.cost import (BEST_FIT_DEVICE_COST,
                                     ENERGY_AWARE_DEVICE_COST)
from repro.core.scheduler.job import rodinia_job
from repro.core.scheduler.kernel import EventKernel
from repro.fleet import (FleetPolicy, RoutingIndex, device_cost_terms,
                         jobs_from_trace, make_fleet, make_router, run_fleet,
                         synthetic_alibaba_rows)
from repro.fleet.index import _compile_device_cost
from repro.fleet.orchestrator import gate_idle_devices
from repro.obs import Tracer

SHAPES = (
    ["a100"] * 4,
    ["a100", "h100"] * 3,
    ["h100"] * 2 + ["a100"] * 5,
)


def _jobs(n: int, seed: int = 3, rate: float = 1.0):
    return jobs_from_trace(synthetic_alibaba_rows(n, seed=seed,
                                                  rate_per_s=rate))


def _assert_rank_equal(router, job, pool) -> None:
    """Indexed rank == seed full-sort rank: same device objects, same
    order (name equality alone could hide aliasing — compare identity)."""
    router.use_index = True
    got = list(router.rank(job, pool))
    router.use_index = False
    want = list(router.rank(job, pool))
    router.use_index = True
    assert [d.name for d in got] == [d.name for d in want]
    for a, b in zip(got, want):
        assert a is b


class TestIndexedRankParity:
    @settings(max_examples=12, deadline=None)
    @given(rnd=st.randoms(),
           router_name=st.sampled_from(["best_fit", "energy_aware"]))
    def test_order_matches_seed_sort_under_mutation(self, rnd, router_name):
        """The property: after every mutation a live fleet can undergo —
        placements, gates, wakes, bare epoch bumps, tariff moves, warm
        re-ranks — the indexed order equals the seed sort, on the full
        pool and on arbitrary sub-pools."""
        fleet = make_fleet(list(rnd.choice(SHAPES)))
        router = make_router(router_name, seed=0)
        policy = FleetPolicy(router)
        kernel = EventKernel(fleet, policy)
        router.index = RoutingIndex(kernel)
        jobs = _jobs(20, seed=rnd.randrange(1000))
        for _ in range(20):
            op = rnd.randrange(6)
            if op == 0:
                policy.dispatch_job(kernel, rnd.choice(jobs))
            elif op == 1:
                dev = rnd.choice(fleet)
                if not dev.gated and not dev.has_running:
                    kernel.sync(dev)
                    dev.gate()
                    kernel.bump_epoch(dev)
            elif op == 2:
                dev = rnd.choice(fleet)
                if dev.gated:
                    dev.ungate()
                    kernel.bump_epoch(dev)
            elif op == 3:
                kernel.bump_epoch(rnd.choice(fleet))
            elif op == 4:
                router.price_per_j = rnd.random() * 1e-4
            # op == 5: no mutation — the pure warm-cache re-rank
            probe = rnd.choice(jobs)
            if rnd.random() < 0.6:
                pool = fleet
            else:
                pool = rnd.sample(fleet, rnd.randint(1, len(fleet)))
            _assert_rank_equal(router, probe, pool)

    def test_foreign_pool_falls_back_to_seed_sort(self):
        """A pool holding a device the kernel does not know cannot be
        index-served; ``index.rank`` reports None and the router's seed
        sort handles it."""
        fleet = make_fleet(["a100"] * 3)
        stranger = make_fleet(["h100"])[0]
        router = make_router("best_fit")
        kernel = EventKernel(fleet, FleetPolicy(router))
        router.index = RoutingIndex(kernel)
        job = rodinia_job("gaussian")
        pool = [fleet[0], stranger, fleet[2]]
        assert router.index.rank(router, job, pool) is None
        _assert_rank_equal(router, job, pool)

    def test_compiled_cost_bitwise_matches_cost_model(self):
        """The exec-specialized cost function returns the exact tuple
        ``CostModel.cost(device_cost_terms(...))`` does — float for
        float, not approximately."""
        fleet = make_fleet(["a100", "h100"])
        job = rodinia_job("srad")
        for model in (BEST_FIT_DEVICE_COST, ENERGY_AWARE_DEVICE_COST):
            fn = _compile_device_cost(model)
            for dev in fleet:
                t = device_cost_terms(job, dev, price_per_j=0.37 / 3.6e6)
                assert fn(t.wake_s, t.mem_waste_gb, t.free_after_gb,
                          t.reach_norm, t.compute_deficit, t.load,
                          t.idle_power_w, t.energy_price) == model.cost(t)

    def test_terms_snapshot_matches_device_cost_terms(self):
        """The epoch-keyed snapshot holds the exact device-dependent
        floats ``device_cost_terms`` derives, including after a
        placement perturbs the fleet."""
        fleet = make_fleet(["a100", "a100", "h100"])
        router = make_router("best_fit")
        policy = FleetPolicy(router)
        kernel = EventKernel(fleet, policy)
        job = rodinia_job("euler3d")
        assert policy.dispatch_job(kernel, job) is not None
        idx = router.index
        probe = rodinia_job("gaussian")
        est = probe.est_mem_gb
        for i, dev in enumerate(fleet):
            wake_s, free_gb, reach_norm, load = idx.terms_snapshot(i, dev)
            t = device_cost_terms(probe, dev)
            assert wake_s == t.wake_s
            assert reach_norm == t.reach_norm
            assert load == t.load
            prof_mem = t.mem_waste_gb + est
            assert free_gb - prof_mem == t.free_after_gb

    def test_warm_rerank_hits_the_snapshot_cache(self):
        fleet = make_fleet(["a100"] * 4)
        router = make_router("best_fit")
        kernel = EventKernel(fleet, FleetPolicy(router))
        router.index = RoutingIndex(kernel)
        job = rodinia_job("gaussian")
        list(router.rank(job, fleet))
        idx = router.index
        misses = idx.n_misses
        assert misses > 0
        hits = idx.n_hits
        list(router.rank(job, fleet))
        assert idx.n_misses == misses   # nothing moved: no recompute
        assert idx.n_hits > hits

    def test_stateful_routers_never_bind_an_index(self):
        """round_robin / random rank statefully (rotation, RNG) — the
        index must not intercept them, and the binding logic must not
        attach one."""
        for name in ("round_robin", "random"):
            fleet = make_fleet(["a100", "a100"])
            router = make_router(name, seed=2)
            policy = FleetPolicy(router)
            kernel = EventKernel(fleet, policy)
            assert policy.dispatch_job(kernel, rodinia_job("gaussian")) \
                is not None
            assert getattr(router, "index", None) is None


class TestEndToEndParity:
    @pytest.mark.parametrize("name", ["best_fit", "energy_aware"])
    def test_fleet_metrics_bitwise_equal(self, name):
        def go(use_index):
            router = make_router(name, seed=1)
            router.use_index = use_index
            return run_fleet(make_fleet(["a100", "a100", "h100"]), router,
                             _jobs(40, seed=5))
        assert go(True) == go(False)

    def test_cluster_metrics_bitwise_equal(self):
        def go(use_index):
            tariff = ZoneTariff("tou", 0.05, 0.25, period_s=200.0)
            zones = [
                make_zone("us", ["a100", "a100"], tariff),
                make_zone("eu", ["h100", "a100"], tariff, phase_s=100.0),
            ]
            for z in zones:
                z.router.use_index = use_index
            jobs, origin = cluster_workload(
                zones, jobs_per_zone=12, period_s=200.0, peak_rate=0.6,
                trough_rate=0.1, seed=9)
            return run_cluster(zones, make_zone_router("price_greedy"),
                               jobs, origin=origin)
        assert go(True) == go(False)


class TestAwakeIdleSet:
    def test_invariant_after_consolidating_run(self):
        fleet = make_fleet(["a100"] * 3)
        policy = FleetPolicy(make_router("energy_aware"))
        kernel = EventKernel(fleet, policy)
        kernel.run(_jobs(24, seed=2, rate=1.5))
        assert kernel.awake_idle == {
            i for i, d in enumerate(fleet)
            if not d.gated and not d.has_running}
        # energy_aware consolidates: a drained fleet is fully gated
        assert kernel.awake_idle == set()
        assert all(d.gated for d in fleet)

    def test_invariant_after_non_gating_run(self):
        fleet = make_fleet(["a100", "h100"])
        policy = FleetPolicy(make_router("best_fit"))
        kernel = EventKernel(fleet, policy)
        kernel.run(_jobs(16, seed=6, rate=1.0))
        # best_fit never gates: everything idle stays awake-idle
        assert kernel.awake_idle == set(range(len(fleet)))

    def test_gate_idle_devices_respects_subset_pools(self):
        """The cluster layer gates per zone: only the handed sub-pool may
        be touched, exactly as the seed full-scan behaved."""
        fleet = make_fleet(["a100"] * 4)
        kernel = EventKernel(fleet, FleetPolicy(make_router("energy_aware")))
        gate_idle_devices(kernel, fleet[:2])
        assert [d.gated for d in fleet] == [True, True, False, False]
        assert kernel.awake_idle == {2, 3}
        gate_idle_devices(kernel, fleet)
        assert all(d.gated for d in fleet)
        assert kernel.awake_idle == set()


class TestIndexObservability:
    def test_counters_flow_through_the_tracer(self):
        tracer = Tracer()
        run_fleet(make_fleet(["a100", "a100"]), make_router("best_fit"),
                  _jobs(8, seed=4), tracer=tracer)
        names = {r["name"] for r in tracer.records
                 if r.get("type") == "counter"}
        assert {"router.candidates", "router.index_hit",
                "router.index_skip"} <= names
