"""Unit + property tests for the partition FSMs (paper §4.1-4.2)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mig_a100 import MigA100Backend, N_GPC, N_MEM_SLICES
from repro.core.mig_h100 import MigH100Backend
from repro.core.partition_state import enumerate_states, saturated
from repro.core.reachability import (fully_configured_states,
                                     precompute_reachability)
from repro.core.tpu_slices import TpuPodBackend, f_configs, chips_at_depth
from repro.core.partition_manager import PartitionManager


@pytest.fixture(scope="module")
def a100():
    return MigA100Backend()


@pytest.fixture(scope="module")
def tpu():
    return TpuPodBackend()


@pytest.fixture(scope="module", params=[MigA100Backend, MigH100Backend],
                ids=["a100", "h100"])
def mig(request):
    """Both MIG generations — every span-FSM invariant must hold on each."""
    return request.param()


class TestMigSpanInvariants:
    """Backend-parametrized FSM invariants (A100 *and* H100)."""

    def test_profiles_sorted_for_tightest_fit(self, mig):
        mems = [p.mem_gb for p in mig.profiles]
        assert mems == sorted(mems)
        assert mig.profiles[-1].mem_gb == mig.total_mem_gb()

    def test_spans_contiguous_and_starts_legal(self, mig):
        for state in enumerate_states(mig):
            for start, name in state:
                gpcs, _mem, starts = mig.table[name]
                assert start in starts
                assert start + gpcs <= mig.n_gpc
            # and within one state, spans never overlap
            total_span = sum(mig.table[name][0] for _s, name in state)
            assert len(mig._occupied_gpcs(state)) == total_span

    def test_memory_never_oversubscribed(self, mig):
        for state in enumerate_states(mig):
            assert mig._used_mem_slices(state) <= mig.n_mem_slices

    def test_free_inverts_alloc(self, mig):
        s0 = mig.initial_state()
        for prof in mig.profiles:
            for pl in mig.enumerate_placements(s0, prof):
                assert mig.free(pl.next_state, pl.handle) == s0

    def test_reachability_counts_fully_configured(self, mig):
        fcr = precompute_reachability(mig)
        assert fcr[mig.initial_state()] == len(fully_configured_states(mig))
        for s, count in fcr.items():
            assert count >= 1
            if saturated(mig, s):
                assert count == 1

    def test_fusion_fission_roundtrip(self, mig):
        """Small idle partitions merge into a big one and back (scheme B's
        reshape), and a fully-released manager returns to s0."""
        pm = PartitionManager(mig)
        smalls = [pm.allocate(mig.profiles[0]) for _ in range(mig.n_gpc)]
        assert all(smalls)
        big = mig.profiles[-1]
        assert pm.allocate(big) is None           # device is full
        part = pm.allocate_with_reshape(big)      # fusion makes room
        assert part is not None and part.profile.name == big.name
        pm.release(part)
        assert pm.state == mig.initial_state()

    def test_reshape_never_touches_busy(self, mig):
        pm = PartitionManager(mig)
        parts = [pm.allocate(mig.profiles[0]) for _ in range(mig.n_gpc)]
        for p in parts:
            p.busy = True
        assert pm.allocate_with_reshape(mig.profiles[-1]) is None
        assert len(pm.live) == mig.n_gpc          # nothing was destroyed

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                              st.integers(min_value=0, max_value=7),
                              st.booleans()),
                    min_size=3, max_size=30))
    def test_property_failed_reshape_is_exact_noop(self, mig, ops):
        """Random allocate/release/allocate_with_reshape sequences on both
        MIG generations: a failed reshape must restore the exact FSM state,
        the identical live Partition objects (same pids, handles, busy
        flags) and the reconfiguration count."""
        pm = PartitionManager(mig)
        profiles = mig.profiles
        for kind, sel, busy in ops:
            if kind == 0:          # allocate a profile, maybe pin it busy
                part = pm.allocate(profiles[sel % len(profiles)])
                if part is not None:
                    part.busy = busy
            elif kind == 1:        # release an idle partition
                idle = [p for p in pm.live.values() if not p.busy]
                if idle:
                    pm.release(idle[sel % len(idle)])
            else:                  # fusion/fission, biased toward failure
                prof = profiles[-1 - (sel % 2)]
                before_state = pm.state
                before_live = dict(pm.live)
                before_fields = {pid: (p.profile.name, p.handle, p.busy)
                                 for pid, p in pm.live.items()}
                before_n = pm.n_reconfigs
                part = pm.allocate_with_reshape(prof)
                if part is None:
                    assert pm.state == before_state
                    assert pm.live.keys() == before_live.keys()
                    assert all(pm.live[pid] is before_live[pid]
                               for pid in before_live)
                    assert {pid: (p.profile.name, p.handle, p.busy)
                            for pid, p in pm.live.items()} == before_fields
                    assert pm.n_reconfigs == before_n
                else:
                    part.busy = busy
        for p in list(pm.live.values()):
            pm.release(p)
        assert pm.state == mig.initial_state()


class TestMigA100:
    def test_profile_table_matches_paper(self, a100):
        """§4.1: 5GB/10GB/20GB/20GB/40GB profiles with 1/7..7/7 compute."""
        by_name = {p.name: p for p in a100.profiles}
        assert by_name["1g.5gb"].mem_gb == 5.0
        assert by_name["2g.10gb"].mem_gb == 10.0
        assert by_name["3g.20gb"].mem_gb == 20.0
        assert by_name["4g.20gb"].mem_gb == 20.0
        assert by_name["7g.40gb"].mem_gb == 40.0
        assert by_name["1g.5gb"].compute_fraction == pytest.approx(1 / 7)
        assert by_name["7g.40gb"].compute_fraction == pytest.approx(1.0)

    def test_nineteen_fully_configured_states(self, a100):
        """Figure 3 lists exactly 19 valid A100 configurations."""
        assert len(fully_configured_states(a100)) == 19

    def test_initial_reachability_is_19(self, a100):
        fcr = precompute_reachability(a100)
        assert fcr[a100.initial_state()] == 19

    def test_paper_placement_example_last_slice_wins(self, a100):
        """§4.2 worked example: placing the first 1g.5gb on the *last* GPC
        slice preserves strictly more future configurations than any other
        placement (paper quotes 9 vs 7 in memory-tuple granularity; in
        position-refined granularity the ordering is identical)."""
        fcr = precompute_reachability(a100)
        p1g = a100._by_name["1g.5gb"]
        scores = {pl.handle[0]: fcr[pl.next_state]
                  for pl in a100.enumerate_placements(a100.initial_state(), p1g)}
        assert len(scores) == 7  # all 7 GPC starts are legal
        best = max(scores, key=scores.get)
        assert best == 6  # last slice
        assert scores[6] > scores[0]

    def test_memory_slices_never_oversubscribed(self, a100):
        for s in enumerate_states(a100):
            assert a100._used_mem_slices(s) <= N_MEM_SLICES
            assert len(a100._occupied_gpcs(s)) <= N_GPC

    def test_free_inverts_alloc(self, a100):
        s0 = a100.initial_state()
        for prof in a100.profiles:
            for pl in a100.enumerate_placements(s0, prof):
                assert a100.free(pl.next_state, pl.handle) == s0

    def test_two_20gb_partitions_use_4g_and_3g(self, a100):
        """§5.2.1 Ml3: the A100 splits into 4/7- and 3/7-compute 20GB halves."""
        pm = PartitionManager(a100)
        p20 = a100.tightest_profile(20.0)
        first = pm.allocate(p20)
        # force the *other* 20GB profile shape to coexist
        candidates = [p for p in a100.profiles if p.mem_gb == 20.0]
        second = None
        for prof in candidates:
            second = pm.allocate(prof)
            if second:
                break
        assert first is not None and second is not None
        fracs = sorted([first.profile.compute_fraction,
                        second.profile.compute_fraction])
        assert fracs[1] >= 3 / 7  # both halves allocatable simultaneously


class TestTpuPod:
    def test_profiles_cover_valid_v5e_shapes(self, tpu):
        names = [p.name for p in tpu.profiles]
        assert names == ["1x1", "1x2", "2x2", "2x4", "4x4", "4x8", "8x8",
                         "8x16", "16x16"]

    def test_f_configs_recurrence(self):
        assert f_configs(8) == 1
        assert f_configs(7) == 2
        assert f_configs(6) == 5
        assert f_configs(5) == 26

    def test_reachability_closed_form_matches_enumeration_small(self):
        """Cross-validate the closed form against literal Alg. 2 on a small
        pod (depth 3 => 26 full configs)."""
        small = TpuPodBackend(max_depth=3)
        # monkeypatch the pod to depth-3 semantics by restricting profiles
        fcr = precompute_reachability(small)
        assert fcr[small.initial_state()] == small.reachability(
            small.initial_state())

    def test_alloc_free_roundtrip(self, tpu):
        pm = PartitionManager(tpu)
        parts = [pm.allocate(tpu.profiles[i]) for i in (0, 2, 4)]
        assert all(parts)
        for p in parts:
            pm.release(p)
        assert pm.state == tpu.initial_state()

    def test_argmax_derives_best_fit(self, tpu):
        """Splitting the smallest adequate free node maximizes |F_s| — the
        buddy best-fit policy emerges from Alg. 3 rather than being coded."""
        pm = PartitionManager(tpu)
        a = pm.allocate(next(p for p in tpu.profiles if p.name == "8x8"))
        assert a is not None
        b = pm.allocate(next(p for p in tpu.profiles if p.name == "1x1"))
        assert b is not None
        # the 1x1 must be carved from the remaining space next to the 8x8's
        # buddy chain, not from a fresh 8x16 half
        assert b.handle[:1] == a.handle[:1]

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=8), min_size=1,
                    max_size=12))
    def test_property_alloc_never_corrupts_state(self, depths):
        tpu = TpuPodBackend()
        pm = PartitionManager(tpu)
        live = []
        for d in depths:
            prof = next(p for p in tpu.profiles
                        if p.extent == chips_at_depth(d))
            part = pm.allocate(prof)
            if part is None:
                continue
            live.append(part)
            # invariant: total allocated chips never exceed the pod
            assert sum(p.profile.extent for p in live) <= 256
            # invariant: reachability is positive (state remains valid)
            assert tpu.reachability(pm.state) >= 1
        for p in live:
            pm.release(p)
        assert pm.state == tpu.initial_state()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(min_value=4, max_value=8), min_size=2,
                    max_size=10), st.randoms())
    def test_property_free_any_order_coalesces(self, depths, rnd):
        tpu = TpuPodBackend()
        pm = PartitionManager(tpu)
        live = []
        for d in depths:
            prof = next(p for p in tpu.profiles
                        if p.extent == chips_at_depth(d))
            part = pm.allocate(prof)
            if part is not None:
                live.append(part)
        rnd.shuffle(live)
        for p in live:
            pm.release(p)
        assert pm.state == tpu.initial_state()


class TestPartitionManager:
    def test_reshape_merges_idle_partitions(self, a100):
        pm = PartitionManager(a100)
        small = [pm.allocate(a100.profiles[0]) for _ in range(7)]
        assert all(small)
        # no room for a 20GB partition now
        p20 = a100.tightest_profile(20.0)
        assert pm.allocate(p20) is None
        # but merging idle 5GB partitions (fusion) makes room
        part = pm.allocate_with_reshape(p20)
        assert part is not None and part.profile.mem_gb == 20.0

    def test_reshape_never_touches_busy(self, a100):
        pm = PartitionManager(a100)
        parts = [pm.allocate(a100.profiles[0]) for _ in range(7)]
        for p in parts:
            p.busy = True
        p20 = a100.tightest_profile(20.0)
        assert pm.allocate_with_reshape(p20) is None
        assert len(pm.live) == 7  # nothing was destroyed

    def test_failed_reshape_probe_is_reconfig_neutral(self, a100):
        """A failed allocate_with_reshape is a no-op on the device — the
        rollback's restore commits must not count as reconfigurations
        (fleet routers probe placement on every ranked device)."""
        pm = PartitionManager(a100)
        busy = pm.allocate(next(p for p in a100.profiles
                                if p.name == "4g.20gb"))
        busy.busy = True
        assert pm.allocate(a100.profiles[0]) is not None  # idle 1g.5gb
        before = pm.n_reconfigs
        full = next(p for p in a100.profiles if p.name == "7g.40gb")
        assert pm.allocate_with_reshape(full) is None
        assert pm.n_reconfigs == before

    def test_rollback_on_infeasible_reshape(self, tpu):
        pm = PartitionManager(tpu)
        full = pm.allocate(tpu.profiles[-1])  # whole pod
        assert full is not None
        full.busy = True
        extra = pm.allocate_with_reshape(tpu.profiles[0])
        assert extra is None
        assert len(pm.live) == 1
