"""SLO-aware serving growth: the gauges, the cost-model trade tier, the
planner's stay candidate / relief scaling, and the end-to-end policy.

The refactor's bit-for-bit side is pinned in tests/test_kernel_parity.py
(queue-tick gauge emulation vs pre-SLO goldens); this module tests the
*new* behaviour — predicted p99-miss probability traded against a
reconfiguration — at every layer it touches.
"""

import dataclasses

import pytest

from repro.core.memory.timeseries import PeakMemoryPredictor, Prediction
from repro.core.mig_a100 import MigA100Backend
from repro.core.mig_h100 import MigH100Backend
from repro.core.partition_manager import PartitionManager
from repro.core.planner import (SERVING_GROW_COST, CostModel, CostTerms,
                                Grow, PartitionPlanner, Wait, grow_request,
                                serving_grow_cost)
from repro.serving.slo import (PredictiveSLOGauge, QueueTickGauge,
                               RISK_RAMP_START, _ramp, make_gauge)
from repro.serving.sim import (LLMServingModel, ServingConfig,
                               ServingRequest, poisson_requests,
                               run_serving)

GB = 1024 ** 3


# ---------------------------------------------------------------------------
# Cost model: grouped trade tiers
# ---------------------------------------------------------------------------

class TestCostModelTiers:
    def test_grouped_tier_sums_weighted_features(self):
        model = CostModel("trade", (
            (("slo_violation_prob", 10.0), ("reconfig_s", 1.0)),
            ("ladder_rank", 1.0),
        ))
        terms = CostTerms(slo_violation_prob=0.5, reconfig_s=2.0,
                          ladder_rank=3.0)
        assert model.cost(terms) == (0.5 * 10.0 + 2.0, 3.0)

    def test_single_feature_tiers_unchanged(self):
        model = CostModel("plain", (("reconfig_s", 1.0), ("reach", -1.0)))
        terms = CostTerms(reconfig_s=1.5, reach=7.0)
        assert model.cost(terms) == (1.5, -7.0)

    def test_explain_labels_grouped_tier(self):
        out = SERVING_GROW_COST.explain(
            CostTerms(slo_violation_prob=1.0, reconfig_s=0.3))
        assert "slo_violation_prob+reconfig_s" in out

    def test_trade_crossover_at_reconfig_over_penalty(self):
        """Grow beats stay exactly when the expected miss seconds outweigh
        the reconfiguration: prob * penalty > reconfig_s (full relief)."""
        model = serving_grow_cost(miss_penalty_s=10.0)
        stay = CostTerms(slo_violation_prob=0.25, ladder_rank=-1.0)
        grow_cheap = CostTerms(reconfig_s=2.0)    # 0.25*10 > 2.0 -> grow
        grow_dear = CostTerms(reconfig_s=3.0)     # 0.25*10 < 3.0 -> stay
        assert model.cost(grow_cheap) < model.cost(stay)
        assert model.cost(stay) < model.cost(grow_dear)


# ---------------------------------------------------------------------------
# Planner: stay candidate, relief scaling, reach_delta
# ---------------------------------------------------------------------------

def _grown_engine_pm(backend):
    """A pm with one busy engine slice on the smallest profile."""
    pm = PartitionManager(backend)
    part = pm.allocate(backend.profiles[0])
    part.busy = True
    return pm, part


class TestPlannerPressureTrade:
    def test_zero_pressure_stays_put(self):
        backend = MigA100Backend()
        pm, part = _grown_engine_pm(backend)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        state, reconfigs = pm.state, pm.n_reconfigs
        result = planner.place(grow_request(
            backend, part, None, 0.5, reconfig_cost_s=0.3,
            slo_violation_prob=0.0, allow_stay=True))
        assert isinstance(result.action, Wait)
        assert result.partition is part
        assert pm.state == state and pm.n_reconfigs == reconfigs

    def test_certain_miss_buys_growth(self):
        backend = MigA100Backend()
        pm, part = _grown_engine_pm(backend)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        result = planner.place(grow_request(
            backend, part, None, 0.5, reconfig_cost_s=0.3,
            slo_violation_prob=1.0, slo_relief=0.0, allow_stay=True))
        assert isinstance(result.action, Grow)
        assert result.partition is not part
        assert result.partition.profile.mem_gb > part.profile.mem_gb

    def test_stay_wins_ties_at_zero_cost(self):
        """Zero pressure + zero reconfig cost must not buy a gratuitous
        reconfiguration: the stay candidate's ladder_rank=-1 wins the tie."""
        backend = MigA100Backend()
        pm, part = _grown_engine_pm(backend)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        result = planner.place(grow_request(
            backend, part, None, 0.5, reconfig_cost_s=0.0,
            slo_violation_prob=0.0, allow_stay=True))
        assert isinstance(result.action, Wait)

    def test_needed_compute_picks_smallest_sufficient_rung(self):
        """With a forecast compute need, every rung at/above it relieves
        fully, so the memory-tight sufficient rung wins — not the biggest
        slice (h100: 2g.20gb at 2/7, not 7g.80gb)."""
        backend = MigH100Backend()
        pm, part = _grown_engine_pm(backend)        # 1g.10gb, c=1/7
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        result = planner.place(grow_request(
            backend, part, None, 0.0, reconfig_cost_s=0.3,
            slo_violation_prob=0.8, needed_compute=0.25, allow_stay=True))
        assert isinstance(result.action, Grow)
        assert result.partition.profile.name == "2g.20gb"

    def test_relief_defaults_to_compute_ratio(self):
        """Without a forecast need, residual pressure scales with the
        compute ratio — the trade tier then prefers more compute when the
        probability is high enough to dominate the shared reconfig cost."""
        backend = MigA100Backend()
        pm, part = _grown_engine_pm(backend)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        plan = planner.plan(grow_request(
            backend, part, None, 0.0, reconfig_cost_s=0.3,
            slo_violation_prob=1.0, allow_stay=True))
        by_profile = {c.action.placement.profile.name: c
                      for c in plan.candidates
                      if not isinstance(c.action, Wait)}
        small = by_profile["2g.10gb"].terms.slo_violation_prob
        big = by_profile["7g.40gb"].terms.slo_violation_prob
        assert big < small < 1.0

    def test_reach_delta_is_graph_reach_change(self):
        backend = MigA100Backend()
        pm, part = _grown_engine_pm(backend)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        live = pm.reach(pm.state)
        plan = planner.plan(grow_request(backend, part, None, 0.5))
        for cand in plan.candidates:
            assert cand.terms.reach_delta == cand.terms.reach - live


# ---------------------------------------------------------------------------
# Gauges
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FakeEngine:
    cfg: ServingConfig
    model: LLMServingModel
    compute: float
    running: list
    waiting: list
    part_bytes: float = 10 * GB
    last_prediction: Prediction | None = None
    predictor: PeakMemoryPredictor = dataclasses.field(
        default_factory=lambda: PeakMemoryPredictor(max_iter=96))


def _req(rid, arrival, prompt=256, decode=160, generated=0):
    r = ServingRequest(rid=rid, arrival=arrival, prompt_tokens=prompt,
                       decode_tokens=decode)
    r.generated = generated
    r.in_prefill = False
    return r


class TestQueueTickGauge:
    def _engine(self, waiting):
        return FakeEngine(cfg=ServingConfig(), model=LLMServingModel(),
                          compute=0.5, running=[], waiting=waiting)

    def test_counts_consecutive_pressured_ticks(self):
        gauge = QueueTickGauge(3)
        eng = self._engine([_req(0, 0.0)])
        assert gauge.observe(eng, 1.0).violation_prob == 0.0
        assert gauge.observe(eng, 2.0).violation_prob == 0.0
        assert gauge.observe(eng, 3.0).violation_prob == 1.0

    def test_empty_queue_resets_count(self):
        gauge = QueueTickGauge(2)
        busy, idle = self._engine([_req(0, 0.0)]), self._engine([])
        gauge.observe(busy, 1.0)
        gauge.observe(idle, 2.0)          # streak broken
        assert gauge.observe(busy, 3.0).violation_prob == 0.0
        assert gauge.observe(busy, 4.0).violation_prob == 1.0

    def test_attempt_and_reset_zero_the_streak(self):
        eng = self._engine([_req(0, 0.0)])
        for zero in (QueueTickGauge.attempt, QueueTickGauge.reset):
            gauge = QueueTickGauge(2)
            gauge.observe(eng, 1.0)
            gauge.observe(eng, 2.0)
            zero(gauge)
            assert gauge.observe(eng, 3.0).violation_prob == 0.0

    def test_threshold_zero_never_fires(self):
        gauge = QueueTickGauge(0)
        eng = self._engine([_req(0, 0.0)])
        for t in range(1, 50):
            assert gauge.observe(eng, float(t)).violation_prob == 0.0

    def test_emulation_semantics_full_relief_legacy_need(self):
        gauge = QueueTickGauge(20)
        assert gauge.relief == 0.0
        assert gauge.use_predicted_need is False
        assert gauge.trade_rebuild_cost is False


class TestPredictiveGauge:
    def _gauge(self):
        return PredictiveSLOGauge(slo_ttft_s=6.0, slo_tpot_s=0.30)

    def test_idle_engine_has_zero_pressure(self):
        eng = FakeEngine(cfg=ServingConfig(), model=LLMServingModel(),
                         compute=0.5, running=[], waiting=[])
        p = self._gauge().observe(eng, 10.0)
        assert p.violation_prob == 0.0
        assert p.needed_compute == pytest.approx(0.5)

    def test_aged_queue_head_raises_ttft_risk(self):
        model = LLMServingModel()
        cfg = ServingConfig()
        # full batch, each sequence nearly done: the drain itself is short,
        # so the head's elapsed wait is what moves the forecast
        running = [_req(i, 0.0, generated=150) for i in range(cfg.max_batch)]
        fresh = FakeEngine(cfg=cfg, model=model, compute=1.0,
                           running=list(running),
                           waiting=[_req(99, 9.9)])
        aged = FakeEngine(cfg=cfg, model=model, compute=1.0,
                          running=list(running),
                          waiting=[_req(99, 1.0)])
        g = self._gauge()
        assert g.observe(fresh, 10.0).ttft_risk == 0.0
        assert g.observe(aged, 10.0).ttft_risk == 1.0

    def test_needed_compute_rises_with_pressure(self):
        model = LLMServingModel()
        cfg = ServingConfig()
        running = [_req(i, 0.0, generated=10) for i in range(cfg.max_batch)]
        eng = FakeEngine(cfg=cfg, model=model, compute=1 / 7,
                         running=running, waiting=[_req(99, 4.0)])
        p = self._gauge().observe(eng, 10.0)
        assert p.ttft_risk > 0.0
        assert p.needed_compute > 1 / 7

    def test_tpot_risk_tracks_iteration_latency(self):
        model = LLMServingModel()
        cfg = ServingConfig()
        slow = FakeEngine(cfg=cfg, model=model, compute=1 / 7,
                          running=[_req(i, 0.0, generated=5)
                                   for i in range(cfg.max_batch)],
                          waiting=[])
        fast = FakeEngine(cfg=cfg, model=model, compute=1.0,
                          running=[_req(0, 0.0, generated=5)], waiting=[])
        g = self._gauge()
        assert g.observe(slow, 1.0).tpot_risk > 0.0
        assert g.observe(fast, 1.0).tpot_risk == 0.0

    def test_arrival_rate_decays_with_silence(self):
        g = self._gauge()
        for t in (0.0, 0.5, 1.0, 1.5):
            g.note_arrival(t)
        burst = g.arrival_rate(2.0)
        later = g.arrival_rate(60.0)
        assert burst > 1.0
        assert later < 0.1 * burst

    def test_oom_risk_requires_converged_prediction(self):
        model = LLMServingModel()
        cfg = ServingConfig(use_prediction=True)
        pred = Prediction(iteration=10, peak_mem_bytes=50 * GB,
                          converged=False, trend_slope=1.0, sigma=1e9,
                          reuse_at_horizon=0.9)
        eng = FakeEngine(cfg=cfg, model=model, compute=0.5, running=[],
                         waiting=[], last_prediction=pred)
        assert self._gauge().observe(eng, 1.0).oom_risk == 0.0
        eng.last_prediction = dataclasses.replace(pred, converged=True)
        assert self._gauge().observe(eng, 1.0).oom_risk > 0.5

    def test_ramp_shape(self):
        assert _ramp(0.0, 6.0) == 0.0
        assert _ramp(RISK_RAMP_START * 6.0, 6.0) == 0.0
        assert _ramp(6.0, 6.0) == 1.0
        assert _ramp(60.0, 6.0) == 1.0
        mid = 0.5 * (RISK_RAMP_START + 1.0) * 6.0
        assert _ramp(mid, 6.0) == pytest.approx(0.5)


class TestMakeGauge:
    def test_selects_by_config(self):
        assert isinstance(make_gauge(ServingConfig(gauge="slo")),
                          PredictiveSLOGauge)
        assert isinstance(make_gauge(ServingConfig(gauge="queue_ticks")),
                          QueueTickGauge)

    def test_zero_ticks_disables_pressure_growth(self):
        gauge = make_gauge(ServingConfig(gauge="slo",
                                         scale_up_queue_ticks=0))
        assert isinstance(gauge, QueueTickGauge)
        assert gauge.threshold == 0

    def test_unknown_gauge_raises(self):
        with pytest.raises(ValueError, match="unknown SLO gauge"):
            make_gauge(ServingConfig(gauge="psychic"))


# ---------------------------------------------------------------------------
# Predictor: graded OOM risk
# ---------------------------------------------------------------------------

class TestOomRisk:
    def _pred(self, peak_gb, sigma, reuse=1.0):
        return Prediction(iteration=20, peak_mem_bytes=peak_gb * GB,
                          converged=True, trend_slope=0.0,
                          sigma=sigma * GB, reuse_at_horizon=reuse)

    def test_monotone_in_partition_size(self):
        p = PeakMemoryPredictor(max_iter=64)
        pred = self._pred(20.0, sigma=2.0)
        risks = [p.oom_risk(gb * GB, pred) for gb in (10, 20, 40, 80)]
        assert risks == sorted(risks, reverse=True)
        assert risks[0] > 0.99 and risks[-1] < 0.01

    def test_zero_sigma_degenerates_to_threshold(self):
        p = PeakMemoryPredictor(max_iter=64)
        pred = self._pred(20.0, sigma=0.0)
        assert p.oom_risk(19.0 * GB, pred) == 1.0
        assert p.oom_risk(21.0 * GB, pred) == 0.0

    def test_risk_is_half_at_fit_mean(self):
        """The reported peak carries the z*sigma*reuse margin; at the
        partition equal to the stripped mean the tail mass is 1/2."""
        p = PeakMemoryPredictor(max_iter=64)
        pred = self._pred(20.0, sigma=1.0, reuse=0.8)
        mean = pred.peak_mem_bytes - p.z * 1.0 * GB * 0.8
        assert p.oom_risk(mean, pred) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Live-JAX engine: the priced restart trade
# ---------------------------------------------------------------------------

class TestServeEngineTrade:
    def _engine(self, **ecfg_kw):
        from repro.serving.engine import EngineConfig, ServeEngine
        eng = object.__new__(ServeEngine)       # decision logic only
        eng.ecfg = EngineConfig(**ecfg_kw)
        eng.predictor = PeakMemoryPredictor(max_iter=64)
        return eng

    def test_priced_trade_fires_on_expected_crash_cost(self):
        pred = Prediction(iteration=20, peak_mem_bytes=20 * GB,
                          converged=True, trend_slope=0.0, sigma=2.0 * GB,
                          reuse_at_horizon=1.0)
        part = 18.0 * GB    # below the margined peak: risk well under 1
        binary = self._engine()
        priced = self._engine(crash_cost_s=30.0, restart_cost_s=0.5)
        timid = self._engine(crash_cost_s=0.01, restart_cost_s=10.0)
        assert binary._restart_now(part, pred)      # will_oom: peak > part
        assert priced._restart_now(part, pred)      # risk * 30 > 0.5
        assert not timid._restart_now(part, pred)   # risk * 0.01 < 10

    def test_priced_trade_waits_for_convergence(self):
        pred = Prediction(iteration=3, peak_mem_bytes=50 * GB,
                          converged=False, trend_slope=0.0, sigma=0.0,
                          reuse_at_horizon=1.0)
        priced = self._engine(crash_cost_s=30.0, restart_cost_s=0.5)
        assert not priced._restart_now(10 * GB, pred)


# ---------------------------------------------------------------------------
# End to end
# ---------------------------------------------------------------------------

class TestSLOServingEndToEnd:
    def test_policy_names_carry_the_gauge(self):
        assert ServingConfig(policy="dynamic").name == "dynamic+slo+pred"
        assert ServingConfig(policy="dynamic",
                             gauge="queue_ticks").name == "dynamic+pred"
        assert ServingConfig(policy="dynamic", use_prediction=False,
                             gauge="queue_ticks").name == "dynamic"
        assert ServingConfig(policy="static").name == "static"

    def test_slo_growth_beats_queue_tail_on_h100(self):
        def reqs():
            return poisson_requests(200, rate_per_s=2.5, seed=11)
        slo = run_serving(["h100"], ServingConfig(
            policy="dynamic", n_engines=2, gauge="slo"), reqs())
        queue = run_serving(["h100"], ServingConfig(
            policy="dynamic", n_engines=2, use_prediction=False,
            gauge="queue_ticks"), reqs())
        assert slo.n_completed == queue.n_completed == 200
        assert slo.p99_ttft <= slo.p99_tpot * 1e9   # sanity: finite
        assert slo.p99_ttft < queue.p99_ttft
        assert slo.n_scaleups >= 1

    def test_zero_ticks_disables_pressure_growth_end_to_end(self):
        m = run_serving(["a100"], ServingConfig(
            policy="dynamic", n_engines=2, use_prediction=False,
            scale_up_queue_ticks=0),
            poisson_requests(150, rate_per_s=2.5, seed=11))
        assert m.n_scaleups == 0

    def test_seeded_determinism_identical_serving_metrics(self):
        """Two identically-seeded SLO-aware runs produce bit-identical
        ServingMetrics — full dataclass equality, mirroring the
        ClusterMetrics determinism test (EWMA gauges, forecasts and the
        trade tier must all be free of hidden nondeterminism)."""
        cfg = ServingConfig(policy="dynamic", n_engines=2, gauge="slo")
        runs = [run_serving(["a100", "h100"], cfg,
                            poisson_requests(180, rate_per_s=2.5, seed=29))
                for _ in range(2)]
        assert dataclasses.asdict(runs[0]) == dataclasses.asdict(runs[1])

    def test_miss_penalty_scales_growth_appetite(self):
        """A near-zero miss penalty makes the stay candidate win every
        pressure trade: no scale-ups; the default penalty grows."""
        def reqs():
            return poisson_requests(200, rate_per_s=2.5, seed=11)
        eager = run_serving(["h100"], ServingConfig(
            policy="dynamic", n_engines=2, gauge="slo"), reqs())
        never = run_serving(["h100"], ServingConfig(
            policy="dynamic", n_engines=2, gauge="slo",
            slo_miss_penalty_s=1e-9), reqs())
        assert eager.n_scaleups >= 1
        assert never.n_scaleups == 0
        assert never.p99_ttft >= eager.p99_ttft

    def test_pressure_metrics_stay_consistent(self):
        m = run_serving(["a100"], ServingConfig(
            policy="dynamic", n_engines=2, gauge="slo"),
            poisson_requests(150, rate_per_s=2.5, seed=11))
        assert m.n_completed + m.n_dropped == 150
        assert m.goodput_rps <= m.throughput_rps + 1e-12
        assert m.n_reconfigs >= 2 + m.n_scaleups  # engine carves + grows
