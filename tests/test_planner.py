"""Tests for the unified partition planner (core/planner/): the compiled
transition graph, the cost model, plan search/execution, and the planner's
exact equivalence with the pre-planner placement ladder."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import reachability
from repro.core.mig_a100 import MigA100Backend
from repro.core.mig_h100 import MigH100Backend
from repro.core.mig_span import MigSpanBackend
from repro.core.partition_manager import PartitionManager
from repro.core.partition_state import enumerate_states
from repro.core.planner import (SCHEME_B_COST, SERVING_GROW_COST, CostModel,
                                CostTerms, FreshAllocate, Grow,
                                PartitionPlanner, ReshapeFuseFission,
                                ReuseIdle, Wait, compile_transition_graph,
                                grow_ladder, grow_request, place_request,
                                placement_ladder)
from repro.core.scheduler.energy import A100_POWER
from repro.core.scheduler.events import DeviceSim
from repro.core.scheduler.job import Job


@pytest.fixture(scope="module")
def a100():
    return MigA100Backend()


@pytest.fixture(scope="module", params=[MigA100Backend, MigH100Backend],
                ids=["a100", "h100"])
def mig(request):
    return request.param()


def _profile(backend, name):
    return next(p for p in backend.profiles if p.name == name)


class TestTransitionGraph:
    def test_graph_matches_online_enumeration_exhaustively(self, mig):
        """Every (state, profile) pair: the compiled placements and the
        precomputed argmax-|F_s| equal the seed online computation."""
        graph = compile_transition_graph(mig)
        assert graph is not None
        for state in enumerate_states(mig):
            for profile in mig.profiles:
                online = mig.enumerate_placements(state, profile)
                assert tuple(online) == graph.placements(state, profile)
                best = (max(online, key=lambda pl: mig.reachability(
                    pl.next_state)) if online else None)
                assert best == graph.best_placement(state, profile)

    def test_graph_is_cached_per_device_table(self):
        g1 = compile_transition_graph(MigA100Backend())
        g2 = compile_transition_graph(MigA100Backend())
        assert g1 is g2    # value-keyed: equivalent instances share a graph

    def test_unsupported_backend_compiles_to_none(self):
        from repro.core.tpu_slices import TpuPodBackend
        assert compile_transition_graph(TpuPodBackend()) is None
        # ... and the manager transparently falls back to enumeration
        pm = PartitionManager(TpuPodBackend())
        assert pm.graph is None
        assert pm.allocate(pm.backend.profiles[0]) is not None

    def test_manager_allocate_uses_graph(self, a100):
        pm = PartitionManager(a100)
        assert pm.allocate(a100.profiles[0]) is not None
        assert pm.graph is not None
        assert pm.graph.n_states == 308      # the A100 FSM, interned

    def test_cache_clear_and_bound(self):
        reachability.clear_reachability_cache()
        compile_transition_graph(MigA100Backend())
        assert len(reachability._CACHE) == 1
        reachability.clear_reachability_cache()
        assert not reachability._CACHE
        # bounded: distinct tiny device tables beyond the bound evict LRU
        for n in range(reachability.MAX_CACHED_BACKENDS + 3):
            b = MigSpanBackend(f"tiny{n}", {"1g": (1, 1, (0,))},
                               n_gpc=1, n_mem_slices=1, mem_slice_gb=1.0 + n)
            compile_transition_graph(b)
        assert len(reachability._CACHE) <= reachability.MAX_CACHED_BACKENDS
        reachability.clear_reachability_cache()


class TestCostModel:
    def test_lexicographic_priorities(self):
        model = CostModel("m", (("reconfig_s", 1.0), ("reach", -1.0)))
        cheap = model.cost(CostTerms(reconfig_s=0.0, reach=1.0))
        rich = model.cost(CostTerms(reconfig_s=0.3, reach=100.0))
        # a strictly cheaper high-priority term beats any low-priority gain
        assert cheap < rich

    def test_negative_weight_prefers_larger(self):
        model = CostModel("m", (("reach", -1.0),))
        assert model.cost(CostTerms(reach=19.0)) < model.cost(
            CostTerms(reach=3.0))

    def test_explain_names_weighted_terms(self):
        s = SCHEME_B_COST.explain(CostTerms(reconfig_s=0.3, reach=7.0))
        assert "reconfig_s=0.3" in s and "reach=-7" in s


class TestPlanSearch:
    def test_reuse_idle_beats_fresh_carve(self, a100):
        pm = PartitionManager(a100)
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        idle = pm.allocate(_profile(a100, "3g.20gb"))
        plan = planner.plan(place_request(a100, 18.0, 0.45,
                                          reconfig_cost_s=0.3))
        assert isinstance(plan.chosen.action, ReuseIdle)
        assert plan.chosen.action.partition is idle
        # both mechanisms were considered and scored
        kinds = {type(c.action) for c in plan.candidates}
        assert kinds == {ReuseIdle, FreshAllocate}
        result = planner.execute(plan)
        assert result.partition is idle and result.setup_s == 0.0

    def test_fresh_carve_pays_reconfig_seconds(self, a100):
        dev = DeviceSim(a100, A100_POWER)
        placed = dev.try_place(Job(name="j", mem_gb=18.0, t_kernel=1.0,
                                   compute_demand=0.45, est_mem_gb=18.0))
        assert placed is not None
        part, setup = placed
        assert part.profile.mem_gb == 20.0
        assert setup == dev.reconfig_cost_s

    def test_fusion_fission_when_fragmented(self, a100):
        pm = PartitionManager(a100)
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        for _ in range(7):
            assert pm.allocate(a100.profiles[0]) is not None
        plan = planner.plan(place_request(a100, 20.0, 0.0,
                                          reconfig_cost_s=0.3))
        assert isinstance(plan.chosen.action, ReshapeFuseFission)
        assert len(plan.chosen.action.consumed) == 7
        result = planner.execute(plan)
        assert result.partition.profile.mem_gb == 20.0
        # the idle partitions were consumed by the fusion
        assert len(pm.live) == 1

    def test_wait_when_nothing_feasible(self, a100):
        pm = PartitionManager(a100)
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        for _ in range(7):
            pm.allocate(a100.profiles[0]).busy = True
        plan = planner.plan(place_request(a100, 20.0, 0.0,
                                          reconfig_cost_s=0.3))
        assert plan.chosen is None
        assert isinstance(plan.action, Wait)
        assert planner.execute(plan) is None
        assert len(pm.live) == 7             # true no-op

    def test_explain_is_human_readable(self, a100):
        pm = PartitionManager(a100)
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        plan = planner.plan(place_request(a100, 18.0, 0.45,
                                          reconfig_cost_s=0.3))
        text = plan.explain()
        assert "scheme_b" in text and ">>" in text
        assert "allocate" in text and "reach=" in text

    def test_grow_releases_then_recarves(self, a100):
        pm = PartitionManager(a100)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        engine = pm.allocate(_profile(a100, "2g.10gb"))
        engine.busy = True
        result = planner.place(grow_request(a100, engine,
                                            predicted_gb=18.0,
                                            compute_demand=0.5))
        assert isinstance(result.action, Grow)
        assert result.partition.profile.mem_gb >= 20.0
        assert len(pm.live) == 1             # the old slice was released

    def test_failed_grow_is_exact_no_op(self, a100):
        """When neighbours hold the space the grow plan degenerates to
        Wait: the engine keeps its exact slice (same Partition object, same
        handle), the FSM state is untouched and the probe counts zero
        reconfigurations."""
        pm = PartitionManager(a100)
        planner = PartitionPlanner(pm, SERVING_GROW_COST)
        engine = pm.allocate(_profile(a100, "4g.20gb"))
        engine.busy = True
        blocker = pm.allocate(_profile(a100, "3g.20gb"))
        blocker.busy = True
        n_before = pm.n_reconfigs
        state_before = pm.state
        result = planner.place(grow_request(a100, engine,
                                            predicted_gb=40.0,
                                            compute_demand=0.5))
        assert isinstance(result.action, Wait)
        assert result.partition is engine            # not even re-pinned
        assert pm.state == state_before
        assert pm.n_reconfigs == n_before
        assert len(pm.live) == 2


class TestLadders:
    def test_placement_ladder_compute_strong_first(self, a100):
        ladder = placement_ladder(a100, 18.0, 0.5)
        assert [p.name for p in ladder] == ["4g.20gb", "3g.20gb"]

    def test_placement_ladder_unknown_memory_starts_smallest(self, a100):
        assert [p.name for p in placement_ladder(a100, None, 0.9)] \
            == ["1g.5gb"]

    def test_grow_ladder_prefers_compute_within_memory_rung(self):
        h100 = MigH100Backend()
        cur = _profile(h100, "1g.10gb")
        ladder = grow_ladder(h100, cur, predicted_gb=None,
                             compute_demand=0.5)
        # every rung is strictly larger in memory; compute-satisfying
        # profiles come first, then the degraded tiers
        assert all(p.mem_gb > cur.mem_gb for p in ladder)
        strong = [p for p in ladder if p.compute_fraction >= 0.5]
        assert ladder[:len(strong)] == strong

    def test_grow_ladder_respects_predicted_need(self, a100):
        cur = _profile(a100, "2g.10gb")
        ladder = grow_ladder(a100, cur, predicted_gb=35.0,
                             compute_demand=0.5)
        assert [p.name for p in ladder] == ["7g.40gb"]


class TestPlannerMatchesPrePlannerLadder:
    """Drive a planner-backed device and a verbatim copy of the deleted
    ``try_place`` double scan through identical random workloads — every
    placement decision must be identical."""

    @staticmethod
    def _reference_try_place(pm, backend, job, reconfig_cost_s):
        # the pre-planner ladder, kept verbatim as the oracle
        candidates = []
        if job.est_mem_gb is not None:
            strong = backend.tightest_profile(job.est_mem_gb,
                                              job.compute_demand)
            if strong is not None:
                candidates.append(strong)
        est = job.est_mem_gb
        weak = (backend.profiles[0] if est is None
                else (backend.tightest_profile(est, 0.0)
                      or backend.profiles[-1]))
        if weak.name not in [c.name for c in candidates]:
            candidates.append(weak)
        for profile in candidates:
            idle = pm.idle_partition_with(profile)
            if idle is not None:
                return idle, 0.0
        for profile in candidates:
            part = (pm.allocate(profile)
                    or pm.allocate_with_reshape(profile))
            if part is not None:
                return part, reconfig_cost_s
        return None

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(
        st.sampled_from([2.0, 4.5, 8.0, 18.0, 24.0, 38.0, 60.0, None]),
        st.floats(min_value=0.0, max_value=1.0),
        st.booleans(),                     # keep the placed partition busy
        st.integers(min_value=0, max_value=5)),  # release selector
        min_size=1, max_size=20))
    def test_property_identical_placements(self, mig, ops):
        pm_new = PartitionManager(mig)
        planner = PartitionPlanner(pm_new, SCHEME_B_COST)
        pm_ref = PartitionManager(mig)
        for i, (est, demand, busy, rel) in enumerate(ops):
            if est is not None and est > mig.total_mem_gb():
                est = mig.total_mem_gb()
            job = Job(name=f"j{i}", mem_gb=est or 1.0, t_kernel=1.0,
                      compute_demand=demand, est_mem_gb=est)
            req = place_request(mig, job.est_mem_gb, job.compute_demand,
                                reconfig_cost_s=0.3)
            result = planner.execute(planner.plan(req))
            ref = self._reference_try_place(pm_ref, mig, job, 0.3)
            if ref is None:
                assert result is None
            else:
                ref_part, ref_setup = ref
                assert result is not None
                assert result.setup_s == ref_setup
                assert result.partition.profile.name == ref_part.profile.name
                assert result.partition.handle == ref_part.handle
                result.partition.busy = busy
                ref_part.busy = busy
            assert pm_new.state == pm_ref.state
            assert pm_new.n_reconfigs == pm_ref.n_reconfigs
            # occasionally release the same idle partition on both sides
            idle_new = [p for p in pm_new.live.values() if not p.busy]
            idle_ref = [p for p in pm_ref.live.values() if not p.busy]
            if idle_new and rel % 3 == 0:
                k = rel % len(idle_new)
                pm_new.release(idle_new[k])
                pm_ref.release(next(p for p in idle_ref
                                    if p.handle == idle_new[k].handle))
                assert pm_new.state == pm_ref.state


def test_fleet_cross_device_restart_is_typed_migrate():
    """An A100 job that outgrows 40GB restarts on the H100 — the fleet
    counts it as a planner Migrate action."""
    from repro.fleet import make_fleet, make_router, run_fleet
    big = Job(name="big", mem_gb=60.0, t_kernel=5.0, compute_demand=0.8,
              est_mem_gb=None)
    small = [Job(name=f"s{i}", mem_gb=4.0, t_kernel=2.0,
                 compute_demand=0.3, est_mem_gb=4.0) for i in range(4)]
    m = run_fleet(make_fleet(["a100", "h100"]), make_router("best_fit"),
                  [big] + small)
    assert m.n_migrations >= 1
    final = [(d, r) for d, r in m.records if r.job == "big"][-1]
    assert final[0] == "h100-0" and final[1].outcome == "done"
