"""Graph-backed admission control: floor math (property-tested against
brute-force enumeration on both MIG generations), the arrival forecast,
and the fleet's reject-or-queue integration."""

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.mig_a100 import MigA100Backend
from repro.core.mig_h100 import MigH100Backend
from repro.core.planner import PartitionPlanner, SCHEME_B_COST, place_request
from repro.core.planner.graph import compile_transition_graph
from repro.core.partition_manager import PartitionManager
from repro.core.reachability import precompute_reachability
from repro.core.scheduler.admission import (AdmissionController,
                                            ArrivalForecast, hosting_states,
                                            reach_floor)
from repro.core.scheduler.job import rodinia_job
from repro.fleet import make_fleet, make_router, poisson_arrivals, run_fleet

BACKENDS = {"a100": MigA100Backend, "h100": MigH100Backend}


# ---------------------------------------------------------------------------
# Brute-force oracle: direct enumeration, no compiled graph involved
# ---------------------------------------------------------------------------

_BRUTE = {}


def brute_hosts(backend, profile, k):
    """state -> can k sequential `profile` placements start there, by
    plain recursive enumeration over ``enumerate_placements``."""
    key = (backend.__class__, profile.name, k)
    if key in _BRUTE:
        return _BRUTE[key]
    fcr = precompute_reachability(backend)
    memo = {}

    def hosts(state, depth):
        if depth == 0:
            return True
        got = memo.get((state, depth))
        if got is None:
            got = any(hosts(pl.next_state, depth - 1)
                      for pl in backend.enumerate_placements(state, profile))
            memo[(state, depth)] = got
        return got

    table = {s: hosts(s, k) for s in fcr}
    _BRUTE[key] = (table, fcr)
    return _BRUTE[key]


def brute_floor(backend, profile, k):
    table, fcr = brute_hosts(backend, profile, k)
    blocked = [fcr[s] for s, ok in table.items() if not ok]
    return max(blocked) + 1 if blocked else 0


def random_state(backend, rng):
    """Walk random placements from the empty device (possibly none)."""
    state = backend.initial_state()
    for _ in range(rng.randint(0, 6)):
        profile = rng.choice(backend.profiles)
        placements = backend.enumerate_placements(state, profile)
        if not placements:
            continue
        state = rng.choice(list(placements)).next_state
    return state


# ---------------------------------------------------------------------------
# Properties (satellite: controller vs brute-force on A100 and H100)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", list(BACKENDS), ids=str)
class TestFloorProperties:
    @settings(max_examples=12, deadline=None)
    @given(k=st.integers(min_value=1, max_value=4),
           prof_idx=st.integers(min_value=0, max_value=10))
    def test_graph_floor_matches_brute_force(self, model, k, prof_idx):
        backend = BACKENDS[model]()
        profile = backend.profiles[prof_idx % len(backend.profiles)]
        graph = compile_transition_graph(backend)
        assert reach_floor(graph, profile, k) == brute_floor(backend,
                                                             profile, k)

    @settings(max_examples=12, deadline=None)
    @given(k=st.integers(min_value=1, max_value=3),
           prof_idx=st.integers(min_value=0, max_value=10))
    def test_floor_guarantees_hosting(self, model, k, prof_idx):
        """The floor is sufficient: EVERY state at/above it hosts k more
        placements — so an admitted job can never strand the forecast."""
        backend = BACKENDS[model]()
        profile = backend.profiles[prof_idx % len(backend.profiles)]
        graph = compile_transition_graph(backend)
        floor = reach_floor(graph, profile, k)
        table, fcr = brute_hosts(backend, profile, k)
        for state, reach in fcr.items():
            if reach >= floor:
                assert table[state], (
                    f"{model}: |F_s|={reach} >= floor={floor} but "
                    f"{k} x {profile.name} placements are impossible")

    @settings(max_examples=12, deadline=None)
    @given(k=st.integers(min_value=1, max_value=3),
           prof_idx=st.integers(min_value=0, max_value=10),
           seed=st.integers(min_value=0, max_value=2 ** 20))
    def test_decision_thresholds_exactly(self, model, k, prof_idx, seed):
        """The controller never admits a placement that lands below the
        floor and never defers one that stays at/above it — checked on a
        randomly-walked FSM state with the decision recomputed from
        direct enumeration."""
        backend = BACKENDS[model]()
        profile = backend.profiles[prof_idx % len(backend.profiles)]
        rng = random.Random(seed)
        pm = PartitionManager(backend)
        pm.state = random_state(backend, rng)
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        plan = planner.plan(place_request(backend, profile.mem_gb, 0.0, 0.3))
        if plan.chosen is None:
            return          # nothing placeable from this state
        ctrl = AdmissionController(horizon_s=10.0, max_lookahead=4)
        # pin the forecast so required_placements == k and the typical
        # profile is exactly the drawn one
        ctrl.forecast._ewma_gap = 10.0 / k
        ctrl.forecast._last_t = 0.0
        ctrl.forecast._ewma_mem = profile.mem_gb
        assert ctrl.required_placements(0.0, shares=1) == k
        # same-memory profiles alias under tightest_profile; the oracle
        # must score whichever the controller resolves to
        typical = ctrl.typical_profile(backend)
        assert typical.mem_gb >= profile.mem_gb
        decision = ctrl.decide(pm, plan, 0.0, shares=1)
        reach_after = backend.reachability(_chosen_state(plan, pm))
        assert decision.reach_after == reach_after
        assert decision.admit == (reach_after >= brute_floor(backend,
                                                             typical, k))


def _chosen_state(plan, pm):
    """The FSM state the chosen action would leave, from the action itself
    (independent of the planner's cached reach term)."""
    from repro.core.planner import FreshAllocate, ReshapeFuseFission
    action = plan.chosen.action
    if isinstance(action, (FreshAllocate, ReshapeFuseFission)):
        return action.placement.next_state
    return pm.state


# ---------------------------------------------------------------------------
# Forecast + controller units
# ---------------------------------------------------------------------------

class TestArrivalForecast:
    def test_rate_tracks_uniform_gaps(self):
        f = ArrivalForecast(alpha=0.5)
        for i in range(20):
            f.observe(i * 2.0, est_mem_gb=8.0)
        assert f.rate_per_s(38.0) == pytest.approx(0.5, rel=0.05)
        assert f.typical_mem_gb == pytest.approx(8.0)

    def test_rate_decays_with_silence(self):
        f = ArrivalForecast()
        for i in range(10):
            f.observe(i * 0.5)
        assert f.rate_per_s(5.0) > 1.0
        assert f.rate_per_s(105.0) < 0.011

    def test_no_arrivals_no_rate(self):
        f = ArrivalForecast()
        assert f.rate_per_s(100.0) == 0.0
        assert f.expected_arrivals(100.0, 30.0) == 0.0
        f.observe(1.0)       # a single arrival has no gap yet
        assert f.rate_per_s(1.0) == 0.0

    def test_required_placements_rounds_not_ceils(self):
        ctrl = AdmissionController(horizon_s=10.0, max_lookahead=4)
        ctrl.forecast._ewma_gap = 1.0
        ctrl.forecast._last_t = 0.0
        # rate 1/s * 10s horizon over 4 devices = 2.5 -> 3 (nearest)
        assert ctrl.required_placements(0.0, shares=4) == 3
        # decayed burst: 0.01 expected arrivals must demand NOTHING, or
        # the last job of every burst would be deferred forever
        ctrl.forecast._last_t = -2000.0
        assert ctrl.required_placements(0.0, shares=1) == 0

    def test_required_placements_caps_at_lookahead(self):
        ctrl = AdmissionController(horizon_s=100.0, max_lookahead=4)
        ctrl.forecast._ewma_gap = 0.1
        ctrl.forecast._last_t = 0.0
        assert ctrl.required_placements(0.0) == 4


class TestControllerDecisions:
    def _plan(self, backend, pm):
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        return planner.plan(place_request(backend, 5.0, 0.0, 0.3))

    def test_quiet_forecast_admits_everything(self):
        backend = MigA100Backend()
        pm = PartitionManager(backend)
        ctrl = AdmissionController()
        d = ctrl.decide(pm, self._plan(backend, pm), t=0.0)
        assert d.admit and d.floor == 0
        assert "no forecast arrivals" in d.reason

    def test_uncompiled_backend_admits(self):
        from repro.core.tpu_slices import TpuPodBackend
        backend = TpuPodBackend()
        pm = PartitionManager(backend)
        planner = PartitionPlanner(pm, SCHEME_B_COST)
        plan = planner.plan(place_request(backend, 8.0, 0.0, 0.3))
        ctrl = AdmissionController()
        ctrl.forecast._ewma_gap = 0.1     # hot forecast
        ctrl.forecast._last_t = 0.0
        assert ctrl.decide(pm, plan, t=0.0).admit

    def test_describe_names_the_verdict(self):
        backend = MigA100Backend()
        pm = PartitionManager(backend)
        ctrl = AdmissionController()
        d = ctrl.decide(pm, self._plan(backend, pm), t=0.0)
        assert d.describe().startswith("admit:")


# ---------------------------------------------------------------------------
# Fleet integration: reject-or-queue, never drop, never deadlock
# ---------------------------------------------------------------------------

def _burst(n, rate, seed=13):
    names = ["myocyte", "gaussian", "srad", "euler3d", "particlefilter",
             "nw", "lavamd", "hotspot3d", "cfd_full"]
    return poisson_arrivals([rodinia_job(names[i % len(names)], i)
                             for i in range(n)], rate_per_s=rate, seed=seed)


class TestFleetAdmission:
    def test_deferral_queues_and_eventually_completes(self):
        m = run_fleet(make_fleet(["a100", "h100"]), make_router("best_fit"),
                      _burst(40, rate=2.0),
                      admission=AdmissionController(horizon_s=20.0))
        assert m.n_jobs == 40
        assert m.n_admission_deferrals >= 1
        assert m.mean_jct > 0 and m.makespan > 0

    def test_without_admission_metrics_are_legacy(self):
        a = run_fleet(make_fleet(["a100", "h100"]), make_router("best_fit"),
                      _burst(24, rate=0.8))
        assert a.n_admission_deferrals == 0
        assert a.n_admission_overrides == 0

    def test_admission_changes_placement_under_burst(self):
        base = run_fleet(make_fleet(["a100", "h100"]),
                         make_router("best_fit"), _burst(40, rate=2.0))
        gated = run_fleet(make_fleet(["a100", "h100"]),
                          make_router("best_fit"), _burst(40, rate=2.0),
                          admission=AdmissionController(horizon_s=20.0))
        assert gated.n_admission_deferrals >= 1
        # deferral trades latency for reachability headroom, never work
        assert gated.n_jobs == base.n_jobs == 40

    def test_starvation_escape_overrides_floor(self):
        """A forecast pinned hot forever must not starve the queue: the
        stall path force-admits once nothing external is pending."""
        ctrl = AdmissionController(horizon_s=30.0, retry_s=None)

        class PinnedForecast(ArrivalForecast):
            def rate_per_s(self, t):
                return 10.0      # never decays

        pinned = PinnedForecast()
        ctrl.forecast = pinned
        m = run_fleet(make_fleet(["a100"]), make_router("best_fit"),
                      _burst(6, rate=5.0), admission=ctrl)
        assert m.n_jobs == 6
        assert m.n_admission_overrides >= 1

    def test_deterministic_with_admission(self):
        import dataclasses
        runs = []
        for _ in range(2):
            m = run_fleet(make_fleet(["a100", "h100"]),
                          make_router("best_fit"), _burst(30, rate=1.5),
                          admission=AdmissionController(horizon_s=15.0))
            runs.append((m.makespan, m.energy_j, m.mean_jct,
                         m.n_admission_deferrals, m.n_admission_overrides,
                         dataclasses.asdict(m)["per_device"]))
        assert runs[0] == runs[1]


class TestHostingDP:
    def test_hosting_states_k1_is_placeability(self):
        backend = MigA100Backend()
        graph = compile_transition_graph(backend)
        profile = backend.profiles[0]
        hosts = hosting_states(graph, profile, 1)
        for sid, state in enumerate(graph.states):
            assert hosts[sid] == bool(
                backend.enumerate_placements(state, profile))

    def test_hosting_monotone_in_k(self):
        backend = MigH100Backend()
        graph = compile_transition_graph(backend)
        profile = backend.profiles[2]
        h1 = hosting_states(graph, profile, 1)
        h3 = hosting_states(graph, profile, 3)
        for a, b in zip(h3, h1):
            if a:
                assert b          # hosting 3 implies hosting 1

    def test_floor_zero_for_k_zero(self):
        backend = MigA100Backend()
        graph = compile_transition_graph(backend)
        assert reach_floor(graph, backend.profiles[0], 0) == 0

    def test_floor_monotone_in_k(self):
        backend = MigA100Backend()
        graph = compile_transition_graph(backend)
        profile = backend.profiles[0]
        floors = [reach_floor(graph, profile, k) for k in range(1, 5)]
        assert floors == sorted(floors)
