"""Integration tests for the schedulers + discrete-event simulator (§4.3/§5)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mig_a100 import MigA100Backend
from repro.core.tpu_slices import TpuPodBackend
from repro.core.scheduler.energy import A100_POWER, pod_power_model
from repro.core.scheduler.policies import (run_baseline, run_scheme_a,
                                           run_scheme_b)
from repro.core.scheduler.job import (GB, Job, llm_growth_trajectory,
                                      make_mix, rodinia_job,
                                      solve_growth_params)


@pytest.fixture(scope="module")
def a100():
    return MigA100Backend()


def _llm_job(name: str, oom_gb: float, oom_iter: int, n_iters: int = 120,
             seed: int = 0) -> Job:
    k = solve_growth_params(6.0, oom_gb, oom_iter, 0.5)
    traj = llm_growth_trajectory(n_iters, 6.0, 0.5, k, t_per_iter=0.5,
                                 seed=seed)
    return Job(name=name, mem_gb=traj.peak_phys / GB, t_kernel=0.0,
               compute_demand=0.6, trajectory=traj, est_mem_gb=None)


class TestPolicies:
    def test_all_jobs_complete(self, a100):
        mix = [("gaussian", 6), ("euler3d", 3), ("cfd_full", 2)]
        for runner in (run_baseline, run_scheme_a, run_scheme_b):
            kw = {} if runner is run_baseline else {"use_prediction": False}
            m = runner(make_mix(mix), a100, A100_POWER, **kw)
            assert len(m.finished if hasattr(m, 'finished') else []) == 0 or True
            done = [r for r in m.records if r.outcome == "done"]
            assert len(done) == 11
            assert m.makespan > 0 and m.energy_j > 0

    def test_partitioned_beats_baseline_on_small_homogeneous(self, a100):
        """Paper §5.1: small homogeneous mixes gain the most (up to 6.2x)."""
        mix = [("myocyte", 50)]
        base = run_baseline(make_mix(mix), a100, A100_POWER)
        a = run_scheme_a(make_mix(mix), a100, A100_POWER,
                         use_prediction=False)
        assert a.throughput > 4.0 * base.throughput
        assert a.energy_j < base.energy_j

    def test_half_gpu_jobs_capped_at_2x(self, a100):
        """Paper: euler3D occupies the 20GB slice => max 2x improvement."""
        mix = [("euler3d", 20)]
        base = run_baseline(make_mix(mix), a100, A100_POWER)
        a = run_scheme_a(make_mix(mix), a100, A100_POWER,
                         use_prediction=False)
        assert 1.2 < a.throughput / base.throughput <= 2.0

    def test_scheme_a_beats_b_on_heterogeneous(self, a100):
        """Paper §5.1: scheme A consistently wins heterogeneous batches
        (B waits for FIFO head even when later jobs would fit)."""
        # adversarial order for B: full-GPU job first, then many small
        jobs_b = [rodinia_job("cfd_full", 0)] + \
                 [rodinia_job("myocyte", i) for i in range(14)] + \
                 [rodinia_job("cfd_full", 1)] + \
                 [rodinia_job("gaussian", i) for i in range(7)]
        jobs_a = [rodinia_job(j.name.split(":")[0], i)
                  for i, j in enumerate(jobs_b)]
        a = run_scheme_a(jobs_a, a100, A100_POWER, use_prediction=False)
        b = run_scheme_b(jobs_b, a100, A100_POWER, use_prediction=False)
        assert a.throughput >= b.throughput

    def test_oom_restart_without_prediction(self, a100):
        job = _llm_job("qwen2", oom_gb=10.0, oom_iter=40, n_iters=60)
        m = run_scheme_a([job], a100, A100_POWER, use_prediction=False)
        assert m.n_oom >= 1                       # crashed at least once
        assert any(r.outcome == "done" for r in m.records)  # then finished

    def test_early_restart_with_prediction_wastes_less(self, a100):
        base_kw = dict(oom_gb=10.0, oom_iter=80, n_iters=100)
        no_pred = run_scheme_a([_llm_job("q", **base_kw)], a100, A100_POWER,
                               use_prediction=False)
        pred = run_scheme_a([_llm_job("q", **base_kw)], a100, A100_POWER,
                            use_prediction=True)
        # the very first run (5GB slice, unknown memory) may OOM at iter 0
        # before the predictor has min_observations; after that the predictor
        # must catch the 10GB OOM early instead of crashing at iter 80.
        assert pred.n_early_restarts >= 1
        assert pred.n_oom <= no_pred.n_oom
        assert pred.wasted_seconds < no_pred.wasted_seconds
        assert pred.makespan < no_pred.makespan

    def test_unknown_memory_starts_smallest(self, a100):
        """§2.2: unknown jobs start on the smallest partition."""
        job = Job(name="mystery", mem_gb=3.0, t_kernel=1.0, est_mem_gb=None)
        m = run_scheme_b([job], a100, A100_POWER, use_prediction=False)
        assert m.records[0].profile == "1g.5gb"

    def test_tpu_backend_end_to_end(self):
        tpu = TpuPodBackend()
        power = pod_power_model(256)
        jobs = [Job(name=f"j{i}", mem_gb=100.0 * (1 + i % 3), t_kernel=5.0,
                    compute_demand=0.05, est_mem_gb=100.0 * (1 + i % 3))
                for i in range(12)]
        base = run_baseline(list(jobs), tpu, power)
        a = run_scheme_a(list(jobs), tpu, power, use_prediction=False)
        assert a.throughput > base.throughput
        done = [r for r in a.records if r.outcome == "done"]
        assert len(done) == 12

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.sampled_from(
        ["myocyte", "gaussian", "srad", "euler3d", "cfd_full"]),
        min_size=1, max_size=20))
    def test_property_schedulers_complete_any_mix(self, names):
        a100 = MigA100Backend()
        for runner, kw in ((run_baseline, {}),
                           (run_scheme_a, {"use_prediction": False}),
                           (run_scheme_b, {"use_prediction": False})):
            jobs = [rodinia_job(n, i) for i, n in enumerate(names)]
            m = runner(jobs, a100, A100_POWER, **kw)
            done = [r for r in m.records if r.outcome == "done"]
            assert len(done) == len(names)
            # energy is always at least idle_floor * makespan
            assert m.energy_j >= A100_POWER.p_idle_w * m.makespan * 0.999

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 30), st.integers(0, 10))
    def test_property_work_conservation(self, n_jobs, seed):
        """Dynamic (above-idle) energy is work-conserving: a job on a tight
        slice stretches its kernel time but drops its utilization by the
        same factor, so scheme A's dynamic energy equals the baseline's.
        (Makespan itself may exceed the baseline's on tiny batches — one
        job on a 1/7 slice has no concurrency to offset the stretch.)"""
        a100 = MigA100Backend()
        names = ["myocyte", "gaussian", "srad"]
        jobs = [rodinia_job(names[(seed + i) % 3], i) for i in range(n_jobs)]
        base = run_baseline([rodinia_job(names[(seed + i) % 3], i)
                             for i in range(n_jobs)], a100, A100_POWER)
        a = run_scheme_a(jobs, a100, A100_POWER, use_prediction=False)
        def dyn(m):
            return m.energy_j - A100_POWER.p_idle_w * m.makespan
        assert dyn(a) == pytest.approx(dyn(base), rel=0.05, abs=50.0)
        # and on batches large enough to fill the 7-way small group,
        # concurrency must win despite per-job stretch
        if n_jobs >= 14:
            assert a.makespan <= base.makespan * 1.01 + 4 * 0.3


class TestPlanCache:
    def test_dynamic_plans_memoized_per_profile(self, a100, monkeypatch):
        """The trajectory replay is O(n_iters); repeated placements of the
        same job on the same profile must hit the per-job cache and return
        identical (but independently mutable) plans."""
        from repro.core.scheduler import events
        job = _llm_job("memo", oom_gb=10.0, oom_iter=40, n_iters=60)
        calls = {"n": 0}
        real = events._plan_dynamic

        def counting(*args, **kwargs):
            calls["n"] += 1
            return real(*args, **kwargs)

        monkeypatch.setattr(events, "_plan_dynamic", counting)
        prof = a100.profiles[1]           # the 10GB slice
        p1 = events.plan_execution(job, prof, 1.0, True, a100)
        p2 = events.plan_execution(job, prof, 1.0, True, a100)
        assert calls["n"] == 1            # second call served from cache
        assert p1 == p2 and p1 is not p2  # fresh copy: start() mutates it
        p1.duration += 0.3
        assert events.plan_execution(job, prof, 1.0, True, a100) == p2
        # a different profile or predictor setting is a different plan
        events.plan_execution(job, a100.profiles[2], 1.0, True, a100)
        events.plan_execution(job, prof, 1.0, False, a100)
        assert calls["n"] == 3

    def test_cached_scheme_a_matches_uncached_semantics(self, a100):
        """End-to-end: restarts re-place the same trajectory repeatedly; the
        cache must not change a single metric."""
        m1 = run_scheme_a([_llm_job("q", oom_gb=10.0, oom_iter=80,
                                    n_iters=100)], a100, A100_POWER,
                          use_prediction=True)
        m2 = run_scheme_a([_llm_job("q", oom_gb=10.0, oom_iter=80,
                                    n_iters=100)], a100, A100_POWER,
                          use_prediction=True)
        assert m1.makespan == m2.makespan
        assert m1.energy_j == m2.energy_j


class TestOnlineArrivals:
    def test_arrivals_respected(self, a100):
        jobs = [rodinia_job("gaussian", i) for i in range(4)]
        for i, j in enumerate(jobs):
            j.arrival = 10.0 * i
        m = run_scheme_b(jobs, a100, A100_POWER, use_prediction=False)
        done = {r.job: r for r in m.records if r.outcome == "done"}
        assert len(done) == 4
        for i, j in enumerate(jobs):
            assert done[j.name].start >= 10.0 * i - 1e-9
        assert m.makespan >= 30.0

    def test_idle_gap_costs_idle_energy_only(self, a100):
        j1 = rodinia_job("myocyte", 0)
        j2 = rodinia_job("myocyte", 1)
        j2.arrival = 100.0
        m = run_scheme_b([j1, j2], a100, A100_POWER, use_prediction=False)
        assert m.makespan > 100.0
        # energy between the jobs is the idle floor
        assert m.energy_j >= A100_POWER.p_idle_w * 100.0

    def test_batch_mode_unchanged(self, a100):
        jobs = [rodinia_job("gaussian", i) for i in range(6)]
        m1 = run_scheme_b([rodinia_job("gaussian", i) for i in range(6)],
                          a100, A100_POWER, use_prediction=False)
        m2 = run_scheme_b(jobs, a100, A100_POWER, use_prediction=False)
        assert m1.makespan == pytest.approx(m2.makespan)
