"""Tests for the flight recorder + telemetry layer (``repro.obs``).

Three contracts pin the subsystem:

1. **No-op parity** — ``tracer=None`` (the default everywhere) leaves
   every simulation's metrics bit-for-bit identical to a traced run:
   recording must observe, never perturb.
2. **Round-trip** — a trace survives write -> parse -> Chrome export,
   the reader refuses foreign/stale schemas, and planner audits carry
   every candidate's full CostTerms vector plus the deciding tier.
3. **Streaming tails** — the P² estimator is exact below its seed
   buffer, deterministic, and rank-accurate on heavy-tailed streams;
   the ``exact=True`` facade reproduces the sorted-list percentiles
   bit-for-bit (the golden path).
"""

import bisect
import dataclasses
import json
import math
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mig_a100 import MigA100Backend
from repro.core.planner.cost import CostTerms
from repro.core.scheduler.energy import A100_POWER
from repro.core.scheduler.job import make_mix
from repro.core.scheduler.metrics import percentile
from repro.core.scheduler.policies import run_scheme_b
from repro.fleet import make_fleet, make_router, run_fleet
from repro.obs import (Counter, Gauge, MetricsRegistry, P2Quantile,
                       SCHEMA, SCHEMA_VERSION, TailStats, Tracer,
                       read_jsonl, to_chrome_trace)
from repro.obs.counters import SEED_SAMPLES
from repro.obs.report import main as report_main
from repro.serving.sim import ServingConfig, poisson_requests, run_serving

COST_TERM_KEYS = {f.name for f in dataclasses.fields(CostTerms)}

SERVING_CFG = ServingConfig(policy="dynamic", n_engines=2,
                            use_prediction=True, gauge="slo")


def _serving_requests(n=150):
    return poisson_requests(n, rate_per_s=2.5, seed=11)


@pytest.fixture(scope="module")
def traced_serving():
    """One traced SLO serving run shared by the round-trip tests."""
    tracer = Tracer(meta={"suite": "test_obs"})
    metrics = run_serving(["a100"], SERVING_CFG, _serving_requests(),
                          tracer=tracer)
    return tracer, metrics


# ---------------------------------------------------------------------------
# counters / gauges / registry


class TestInstruments:
    def test_counter_monotonic(self):
        c = Counter("requests")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1.0)

    def test_gauge_tracks_extremes(self):
        g = Gauge("queue_depth")
        for v in (3.0, 7.0, 1.0):
            g.set(v)
        assert (g.value, g.max, g.min) == (1.0, 7.0, 1.0)

    def test_registry_create_or_return(self):
        reg = MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        assert reg.tail("t") is reg.tail("t")
        with pytest.raises(TypeError):
            reg.gauge("a")   # already a Counter

    def test_registry_snapshot(self):
        reg = MetricsRegistry()
        reg.counter("n").inc(4)
        reg.gauge("depth").set(2.0)
        for x in (1.0, 2.0, 3.0):
            reg.tail("lat").observe(x)
        snap = reg.snapshot()
        assert snap["n"] == 4
        assert snap["depth"]["max"] == 2.0
        assert snap["lat"]["count"] == 3
        assert snap["lat"]["p50"] == pytest.approx(2.0)


class TestTailStats:
    def test_exact_facade_matches_sorted_list(self):
        """exact=True is the golden path: bit-for-bit the legacy sort."""
        rnd = random.Random(3)
        xs = [rnd.expovariate(0.2) for _ in range(257)]
        tail = TailStats("lat", exact=True)
        for x in xs:
            tail.observe(x)
        for pct in (50, 90, 95, 99, 100):
            assert tail.percentile(pct) == percentile(xs, pct)
        assert tail.mean == pytest.approx(sum(xs) / len(xs))
        assert (tail.min, tail.max) == (min(xs), max(xs))

    def test_untracked_quantile_raises(self):
        tail = TailStats("lat")
        tail.observe(1.0)
        with pytest.raises(KeyError):
            tail.percentile(42)

    def test_empty_is_nan(self):
        assert math.isnan(TailStats("lat").percentile(99))


# ---------------------------------------------------------------------------
# P² streaming quantiles


class TestP2Quantile:
    def test_rejects_degenerate_quantile(self):
        for q in (0.0, 1.0, -0.5, 2.0):
            with pytest.raises(ValueError):
                P2Quantile(q)

    def test_exact_below_seed_buffer(self):
        rnd = random.Random(7)
        xs = [rnd.paretovariate(1.5) for _ in range(SEED_SAMPLES - 1)]
        for k in (1, 5, len(xs)):
            est = P2Quantile(0.99)
            for x in xs[:k]:
                est.observe(x)
            assert est.value == pytest.approx(percentile(xs[:k], 99))

    def test_deterministic(self):
        rnd = random.Random(5)
        xs = [rnd.lognormvariate(0.0, 1.5) for _ in range(2000)]
        a, b = P2Quantile(0.95), P2Quantile(0.95)
        for x in xs:
            a.observe(x)
            b.observe(x)
        assert a.value == b.value

    def test_value_accuracy_on_moderate_heavy_tail(self):
        """Fixed-stream regression: Pareto(1.8) tails within a few %."""
        rnd = random.Random(0)
        xs = [rnd.paretovariate(1.8) for _ in range(5000)]
        for q, tol in ((0.50, 0.02), (0.95, 0.06), (0.99, 0.12)):
            est = P2Quantile(q)
            for x in xs:
                est.observe(x)
            exact = percentile(xs, q * 100)
            assert abs(est.value - exact) <= tol * exact, (q, est.value,
                                                           exact)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 20),
           n=st.integers(min_value=200, max_value=3000),
           shape=st.floats(min_value=1.5, max_value=2.5),
           pareto=st.booleans())
    def test_rank_error_bounded_on_heavy_tails(self, seed, n, shape,
                                               pareto):
        """The sketch guarantee: the pXX estimate lands within 8 rank
        points of XX on any heavy-tailed stream.  (Value-space error is
        unbounded where the density vanishes — rank error is the honest
        metric, and the one the report's tails inherit.)"""
        rnd = random.Random(seed)
        if pareto:
            xs = [rnd.paretovariate(shape) for _ in range(n)]
        else:
            xs = [rnd.lognormvariate(0.0, shape) for _ in range(n)]
        srt = sorted(xs)
        for q in (0.50, 0.95, 0.99):
            est = P2Quantile(q)
            for x in xs:
                est.observe(x)
            assert srt[0] <= est.value <= srt[-1]
            rank = bisect.bisect_right(srt, est.value) / n
            assert abs(rank - q) <= 0.08, (q, rank, est.value)


# ---------------------------------------------------------------------------
# tracer no-op parity


class TestTracerNoopParity:
    def test_serving_metrics_unperturbed(self):
        plain = run_serving(["a100"], SERVING_CFG, _serving_requests())
        traced = run_serving(["a100"], SERVING_CFG, _serving_requests(),
                             tracer=Tracer())
        assert plain == traced

    def test_batch_metrics_unperturbed(self):
        a100 = MigA100Backend()
        mix = [("gaussian", 4), ("euler3d", 2), ("myocyte", 3)]
        plain = run_scheme_b(make_mix(mix), a100, A100_POWER,
                             use_prediction=False)
        traced = run_scheme_b(make_mix(mix), a100, A100_POWER,
                              use_prediction=False, tracer=Tracer())
        assert plain == traced

    def test_fleet_metrics_unperturbed(self):
        def go(tracer):
            from repro.core.scheduler.job import rodinia_job
            jobs = [rodinia_job("gaussian", i) for i in range(5)]
            return run_fleet(make_fleet(["a100", "a100"]),
                             make_router("best_fit"), jobs, tracer=tracer)
        assert go(None) == go(Tracer())

    def test_fleet_metrics_unperturbed_with_index_counters(self):
        """PR 8's routing index emits per-dispatch counters when traced;
        tracer=None must stay the exact same sim, and the traced run must
        actually carry the index's counter tracks."""
        def go(tracer):
            from repro.core.scheduler.job import rodinia_job
            jobs = [rodinia_job("srad", i) for i in range(6)]
            return run_fleet(make_fleet(["a100", "h100"]),
                             make_router("energy_aware"), jobs,
                             tracer=tracer)
        tracer = Tracer()
        assert go(None) == go(tracer)
        counters = {r["name"] for r in tracer.records
                    if r.get("type") == "counter"}
        assert {"router.candidates", "router.index_hit",
                "router.index_skip"} <= counters


# ---------------------------------------------------------------------------
# trace round-trip + planner audit


class TestTraceRoundTrip:
    def test_jsonl_roundtrip(self, traced_serving, tmp_path):
        tracer, _ = traced_serving
        path = tmp_path / "trace.jsonl"
        n = tracer.write_jsonl(str(path))
        header, records = read_jsonl(str(path))
        assert n == len(tracer.records) == len(records)
        assert header["schema"] == SCHEMA
        assert header["schema_version"] == SCHEMA_VERSION
        assert header["meta"]["suite"] == "test_obs"
        assert "t_end" in header["meta"]
        assert records == json.loads(json.dumps(tracer.records))

    def test_reader_refuses_stale_schema(self, traced_serving, tmp_path):
        tracer, _ = traced_serving
        path = tmp_path / "stale.jsonl"
        header = tracer.header()
        header["schema_version"] = SCHEMA_VERSION + 1
        path.write_text(json.dumps(header) + "\n")
        with pytest.raises(ValueError, match="schema_version"):
            read_jsonl(str(path))

    def test_reader_refuses_foreign_file(self, tmp_path):
        path = tmp_path / "foreign.jsonl"
        path.write_text('{"hello": "world"}\n')
        with pytest.raises(ValueError, match="header"):
            read_jsonl(str(path))
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        with pytest.raises(ValueError, match="empty"):
            read_jsonl(str(empty))

    def test_chrome_export(self, traced_serving):
        tracer, _ = traced_serving
        chrome = to_chrome_trace(tracer.records, tracer.meta)
        json.dumps(chrome)   # must be serializable as-is
        events = chrome["traceEvents"]
        phases = {e["ph"] for e in events}
        assert phases <= {"M", "X", "i", "C"}
        assert "X" in phases and "i" in phases
        for e in events:
            assert isinstance(e["pid"], int)
            if e["ph"] == "X":
                assert e["dur"] >= 0.0
        # per-device slice-occupancy spans: the a100 process exists and
        # carries request/reconfig slices on its engine lanes
        procs = {e["args"]["name"] for e in events
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert "a100-0" in procs
        cats = {e["cat"] for e in events if e["ph"] == "X"}
        assert "request" in cats and "reconfig" in cats

    def test_audit_records_carry_full_cost_vectors(self, traced_serving):
        tracer, metrics = traced_serving
        audits = [r for r in tracer.records if r.get("type") == "audit"]
        assert audits, "SLO serving must audit its grow/wait searches"
        grows = [a for a in audits
                 if a["chosen"] is not None
                 and a["candidates"][a["chosen"]]["action"] != "wait"]
        assert metrics.n_scaleups + metrics.n_early_restarts > 0
        assert grows, "at least one growth decision must be audited"
        for a in audits:
            assert a["tiers"], "cost-model tier labels must be recorded"
            for cand in a["candidates"]:
                assert set(cand["terms"]) == COST_TERM_KEYS
                assert len(cand["cost"]) == len(a["tiers"])
            tier = a["deciding_tier"]
            if tier is not None:
                assert 0 <= tier < len(a["tiers"])
                assert a["deciding_tier_label"] == a["tiers"][tier]


# ---------------------------------------------------------------------------
# report CLI


class TestReportCLI:
    def test_summarizes_valid_trace(self, traced_serving, tmp_path,
                                    capsys):
        tracer, _ = traced_serving
        path = tmp_path / "trace.jsonl"
        tracer.write_jsonl(str(path))
        chrome = tmp_path / "trace.chrome.json"
        assert report_main([str(path), "--chrome", str(chrome)]) == 0
        out = capsys.readouterr().out
        assert "plan searches" in out
        assert "span occupancy" in out
        loaded = json.loads(chrome.read_text())
        assert loaded["traceEvents"]

    def test_exits_2_on_schema_mismatch(self, traced_serving, tmp_path,
                                        capsys):
        tracer, _ = traced_serving
        path = tmp_path / "stale.jsonl"
        header = tracer.header()
        header["schema_version"] = SCHEMA_VERSION + 7
        path.write_text(json.dumps(header) + "\n")
        assert report_main([str(path)]) == 2
        assert "refusing to summarize" in capsys.readouterr().err

    def test_exits_2_on_missing_or_foreign_file(self, tmp_path, capsys):
        assert report_main([str(tmp_path / "nope.jsonl")]) == 2
        foreign = tmp_path / "foreign.jsonl"
        foreign.write_text('{"rows": []}\n')
        assert report_main([str(foreign)]) == 2
        assert "refusing to summarize" in capsys.readouterr().err
