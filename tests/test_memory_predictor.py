"""Tests for the time-series memory predictor (paper §3, Algorithm 1)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.memory.timeseries import (PeakMemoryPredictor,
                                          run_to_convergence)
from repro.core.memory.accountant import MemoryAccountant, pytree_nbytes
from repro.core.memory.workspace import parse_cublas_workspace_config
from repro.core.scheduler.job import (GB, llm_growth_trajectory,
                                      solve_growth_params)


class TestPredictor:
    def test_exact_linear_trajectory_recovered(self):
        """Clean linear data: prediction == a*T + b with zero sigma."""
        p = PeakMemoryPredictor(max_iter=100, converge_k=2)
        out = None
        for t in range(10):
            out = p.observe(req_mem=1000.0 + 50.0 * t, reuse_ratio=1.0)
        assert out.converged
        assert out.sigma == pytest.approx(0.0, abs=1e-6)
        assert out.peak_mem_bytes == pytest.approx(1000.0 + 50.0 * 99, rel=1e-6)

    def test_ci_margin_scales_with_noise(self):
        rng = np.random.default_rng(0)
        preds = []
        for noise in (0.0, 100.0):
            p = PeakMemoryPredictor(max_iter=50, converge_k=3)
            for t in range(30):
                p_out = p.observe(1000.0 + 10 * t + rng.normal(0, noise), 1.0)
            preds.append(p_out.peak_mem_bytes)
        assert preds[1] > preds[0]  # z*sigma margin grows with variance

    def test_qwen2_scenario_predict_at_6_vs_oom_at_94(self):
        """The paper's headline result (§2.3): Qwen2-7B on a 10GB slice OOMs
        after 94 iterations; the predictor flags it at iteration 6."""
        k = solve_growth_params(base_gb=6.0, oom_gb=10.0, oom_iter=94,
                                req_gb_per_iter=0.5)
        traj = llm_growth_trajectory(n_iters=120, base_gb=6.0,
                                     req_gb_per_iter=0.5, inv_reuse_slope=k,
                                     t_per_iter=1.0, seed=1)
        assert traj.oom_iteration(10 * GB) == 94
        pred, fired_at = run_to_convergence(traj.req_mem, traj.reuse_ratio,
                                            max_iter=120,
                                            partition_bytes=10 * GB)
        assert fired_at <= 10  # paper: 6th iteration
        assert pred.peak_mem_bytes > 10 * GB

    def test_prediction_error_within_paper_band(self):
        """§5.2.2: average prediction error at 10% of iterations ~15%."""
        errors = []
        for seed in range(8):
            k = solve_growth_params(6.0, 12.0, 80, 0.6)
            traj = llm_growth_trajectory(120, 6.0, 0.6, k, 1.0,
                                         noise_gb=0.15, seed=seed)
            pred, _ = run_to_convergence(traj.req_mem[:12],
                                         traj.reuse_ratio[:12], max_iter=120)
            errors.append(abs(pred.peak_mem_bytes - traj.peak_phys)
                          / traj.peak_phys)
        assert np.mean(errors) < 0.20

    def test_no_false_alarm_on_flat_memory(self):
        p = PeakMemoryPredictor(max_iter=1000)
        for t in range(50):
            out = p.observe(5 * GB, 0.9)
        assert out.converged
        assert not p.will_oom(10 * GB, out)

    def test_will_oom_requires_convergence(self):
        p = PeakMemoryPredictor(max_iter=100)
        out = p.observe(5 * GB, 1.0)
        assert not p.will_oom(1.0, out)  # not converged yet

    @settings(max_examples=40, deadline=None)
    @given(a=st.floats(0.0, 1e8), b=st.floats(1e6, 1e9),
           k=st.floats(0.0, 0.5))
    def test_property_prediction_upper_bounds_trend(self, a, b, k):
        """With the 99% CI margin, the prediction never falls below the pure
        trend value at the horizon for noiseless inputs."""
        p = PeakMemoryPredictor(max_iter=200)
        out = None
        for t in range(12):
            out = p.observe(b + a * t, 1.0 / (1.0 + k * t))
        trend_at_T = (b + a * 199) / (1.0 + k * 199)
        assert out.peak_mem_bytes >= trend_at_T * 0.99

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(1e3, 1e12), min_size=3, max_size=40))
    def test_property_predictor_total_function(self, series):
        """Any positive series yields a finite prediction (robustness)."""
        p = PeakMemoryPredictor(max_iter=100)
        for m in series:
            out = p.observe(m, 0.5)
        assert np.isfinite(out.peak_mem_bytes)
        assert out.peak_mem_bytes >= 0.0


class TestAccountant:
    def test_pytree_nbytes(self):
        tree = {"a": np.zeros((4, 4), np.float32),
                "b": [np.zeros(10, np.int8)]}
        assert pytree_nbytes(tree) == 4 * 4 * 4 + 10

    def test_iteration_stats_feed_predictor(self):
        acc = MemoryAccountant()
        for t in range(5):
            acc.note_alloc(np.zeros(1000, np.float32))
            acc.note_live(np.zeros(500 * (t + 1), np.float32))
            acc.end_iteration()
        req, reuse = acc.series()
        assert len(req) == 5
        assert req[-1] > req[0]            # cumulative requests grow
        assert acc.peak_in_use == 500 * 5 * 4

    def test_reuse_ratio_bounded(self):
        acc = MemoryAccountant()
        acc.note_alloc(1000.0)
        acc.note_live(400.0)
        s = acc.end_iteration()
        assert 0.0 < s.reuse_ratio <= 1.0


class TestWorkspace:
    def test_parse_cublas_config(self):
        assert parse_cublas_workspace_config(":4096:8") == 4096 * 1024 * 8
        assert parse_cublas_workspace_config(":4096:2,:16384:2") == \
            (4096 * 2 + 16384 * 2) * 1024
